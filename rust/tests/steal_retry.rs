//! Regression gate for bounded steal retry with backoff
//! (`StealCfg::retry_backoff` / `retry_max`).
//!
//! The scenario retry exists for: a thief's first victim answers
//! `StealDeny` (here forced deterministically via the chaos layer's
//! `deny_first` knob), and without a retry the thief would sit idle until
//! the next organic idle trigger. With retry enabled the thief re-arms
//! after a bounded exponential backoff and the migration still happens.
//! With `retry_backoff == 0` (the default) the feature is off and the
//! schedule must stay byte-identical to plain `StealCfg::on()`.

use myrmics::apps::skew::{myrmics as skew_myrmics, SkewParams};
use myrmics::config::{HierarchySpec, PlatformConfig, RecoveryCfg, StealCfg};
use myrmics::platform::Platform;
use myrmics::sim::chaos::FaultPlan;
use myrmics::testutil::oracles;

/// The steal-determinism fingerprint tuple: everything that must replay.
#[derive(PartialEq, Eq, Debug)]
struct Fingerprint {
    final_time: u64,
    events: u64,
    msgs: u64,
    tasks_spawned: u64,
    tasks_completed: u64,
    dep_boundary_msgs: u64,
    steal_reqs: u64,
    steal_grants: u64,
    steal_denies: u64,
    tasks_stolen: u64,
    ready_hwm: u64,
}

fn run_skew(steal: StealCfg, chaos: FaultPlan) -> Fingerprint {
    let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
    cfg.policy.steal = steal;
    cfg.chaos = chaos;
    let (reg, main) = skew_myrmics();
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SkewParams {
            tasks: 64,
            task_cycles: 200_000,
            hot_pct: 90,
            groups: 4,
        }));
    });
    let t = plat.run(Some(1 << 44));
    let g = &plat.world().gstats;
    Fingerprint {
        final_time: t,
        events: g.events_processed,
        msgs: g.msgs_total,
        tasks_spawned: g.tasks_spawned,
        tasks_completed: g.tasks_completed,
        dep_boundary_msgs: g.dep_boundary_msgs,
        steal_reqs: g.steal_reqs,
        steal_grants: g.steal_grants,
        steal_denies: g.steal_denies,
        tasks_stolen: g.tasks_stolen,
        ready_hwm: g.ready_queue_hwm,
    }
}

/// A fault plan whose only perturbation is forcing the first `n`
/// `StealReq`s to be denied (all rates zero — no jitter, stalls or
/// starvation).
fn deny_first(n: u32) -> FaultPlan {
    FaultPlan { enabled: true, plan_seed: 7, deny_first: n, ..FaultPlan::none() }
}

/// `with_retry(0, _)` is the do-nothing configuration: the schedule must
/// be byte-identical to plain `StealCfg::on()`.
#[test]
fn retry_disabled_is_byte_identical_to_plain_on() {
    let a = run_skew(StealCfg::on(), FaultPlan::none());
    let b = run_skew(StealCfg::on().with_retry(0, 7), FaultPlan::none());
    assert_eq!(a, b, "retry_backoff == 0 must not change the schedule");
}

/// The headline scenario: the first victims always deny, retry re-arms
/// the thief, and the skewed load still migrates and completes.
#[test]
fn denied_first_attempts_retry_and_still_migrate() {
    let fp = run_skew(StealCfg::on().with_retry(10_000, 4), deny_first(3));
    assert_eq!(fp.tasks_completed, 65, "main + 64 work tasks despite forced denies");
    assert_eq!(fp.tasks_completed, fp.tasks_spawned);
    assert!(fp.steal_denies >= 3, "the forced denies must show up: {fp:?}");
    assert!(fp.tasks_stolen > 0, "retries must still reach a granting victim: {fp:?}");
}

/// Retry-enabled runs (with forced denies in the mix) are still a pure
/// function of the configuration: two runs replay bit-identically.
#[test]
fn retry_runs_replay_bit_identically() {
    let run = || run_skew(StealCfg::on().with_retry(10_000, 4), deny_first(3));
    let a = run();
    let b = run();
    assert_eq!(a, b, "retry + forced-deny run must replay bit-identically");
}

/// Crash interlock: a `StealReq` in flight to a victim that dies can
/// never be answered, so the thief's latch would stay set forever —
/// unless the death declaration synthesizes the `StealDeny` itself and
/// re-arms the thief through the ordinary retry path.
///
/// Phase 1 discovers the hot leaf empirically (stealing off, 100% skew:
/// every work task records the worker it ran on, all in one subtree).
/// Phase 2 re-runs with stealing + recovery enabled and kills exactly
/// that leaf mid-run: the parent keeps aiming its steal requests at the
/// (stale-high) dead child's load estimate, so its request is parked in
/// the dead mailbox when the missed-heartbeat declaration fires.
#[test]
fn crashed_victim_gets_a_synthesized_deny_and_the_run_completes() {
    let build = |steal: StealCfg, recovery: RecoveryCfg| {
        let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
        cfg.policy.steal = steal;
        cfg.recovery = recovery;
        let (reg, main) = skew_myrmics();
        Platform::build_with(cfg, reg, main, |w| {
            w.app = Some(Box::new(SkewParams {
                tasks: 64,
                task_cycles: 200_000,
                hot_pct: 100,
                groups: 4,
            }));
        })
    };
    // Phase 1: with stealing off nothing migrates, so the task table's
    // `worker` fields name the hot subtree directly.
    let mut probe = build(StealCfg::default(), RecoveryCfg::off());
    probe.run(Some(1 << 44));
    let w = probe.world();
    let mut per_leaf = vec![0u64; w.hier.n_scheds];
    for e in w.tasks.iter() {
        if let Some(wk) = e.worker {
            per_leaf[w.hier.leaf_of_worker(wk)] += 1;
        }
    }
    let hot = (0..w.hier.n_scheds)
        .max_by_key(|&s| per_leaf[s])
        .expect("tree has leaves");
    assert!(per_leaf[hot] >= 64, "100% skew must pile onto one leaf: {per_leaf:?}");
    let hot_core = w.hier.sched_core(hot);

    // Phase 2: kill the hot leaf while the work is queued there; restart
    // it long after the heartbeat timeout so death is actually declared.
    let run = || {
        let mut plat = build(StealCfg::on().with_retry(10_000, 8), RecoveryCfg::on());
        plat.eng.sim.install_crash(hot_core, 300_000, Some(1_500_000));
        let t = plat.run_to_quiescence(Some(1 << 44));
        let violations = oracles::check_all(&plat.eng, false);
        let g = &plat.eng.world.gstats;
        (
            t,
            g.events_processed,
            g.tasks_completed,
            g.tasks_spawned,
            g.steal_reqs,
            g.steal_grants,
            g.steal_denies,
            g.crashes,
            g.crash_denies_synth,
            g.tasks_reissued,
            plat.eng.world.done,
            violations,
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "crashed-victim steal run must replay bit-identically");
    let (_, _, completed, spawned, reqs, grants, denies, crashes, synth, reissued, done, violations) =
        a;
    assert!(done, "the run must complete despite the dead victim");
    assert!(violations.is_empty(), "oracles: {violations:?}");
    assert_eq!(crashes, 1, "the installed crash must fire");
    assert_eq!(completed, spawned, "exactly-once completion");
    assert!(
        synth >= 1,
        "the in-flight StealReq to the dead hot leaf must be answered by a \
         synthesized deny: reqs {reqs} grants {grants} denies {denies} synth {synth}"
    );
    assert_eq!(reqs, grants + denies, "steal accounting must balance");
    assert!(reissued > 0, "the dead leaf's queued work must be re-issued");
}

//! Regression gate for bounded steal retry with backoff
//! (`StealCfg::retry_backoff` / `retry_max`).
//!
//! The scenario retry exists for: a thief's first victim answers
//! `StealDeny` (here forced deterministically via the chaos layer's
//! `deny_first` knob), and without a retry the thief would sit idle until
//! the next organic idle trigger. With retry enabled the thief re-arms
//! after a bounded exponential backoff and the migration still happens.
//! With `retry_backoff == 0` (the default) the feature is off and the
//! schedule must stay byte-identical to plain `StealCfg::on()`.

use myrmics::apps::skew::{myrmics as skew_myrmics, SkewParams};
use myrmics::config::{HierarchySpec, PlatformConfig, StealCfg};
use myrmics::platform::Platform;
use myrmics::sim::chaos::FaultPlan;

/// The steal-determinism fingerprint tuple: everything that must replay.
#[derive(PartialEq, Eq, Debug)]
struct Fingerprint {
    final_time: u64,
    events: u64,
    msgs: u64,
    tasks_spawned: u64,
    tasks_completed: u64,
    dep_boundary_msgs: u64,
    steal_reqs: u64,
    steal_grants: u64,
    steal_denies: u64,
    tasks_stolen: u64,
    ready_hwm: u64,
}

fn run_skew(steal: StealCfg, chaos: FaultPlan) -> Fingerprint {
    let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
    cfg.policy.steal = steal;
    cfg.chaos = chaos;
    let (reg, main) = skew_myrmics();
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SkewParams {
            tasks: 64,
            task_cycles: 200_000,
            hot_pct: 90,
            groups: 4,
        }));
    });
    let t = plat.run(Some(1 << 44));
    let g = &plat.world().gstats;
    Fingerprint {
        final_time: t,
        events: g.events_processed,
        msgs: g.msgs_total,
        tasks_spawned: g.tasks_spawned,
        tasks_completed: g.tasks_completed,
        dep_boundary_msgs: g.dep_boundary_msgs,
        steal_reqs: g.steal_reqs,
        steal_grants: g.steal_grants,
        steal_denies: g.steal_denies,
        tasks_stolen: g.tasks_stolen,
        ready_hwm: g.ready_queue_hwm,
    }
}

/// A fault plan whose only perturbation is forcing the first `n`
/// `StealReq`s to be denied (all rates zero — no jitter, stalls or
/// starvation).
fn deny_first(n: u32) -> FaultPlan {
    FaultPlan { enabled: true, plan_seed: 7, deny_first: n, ..FaultPlan::none() }
}

/// `with_retry(0, _)` is the do-nothing configuration: the schedule must
/// be byte-identical to plain `StealCfg::on()`.
#[test]
fn retry_disabled_is_byte_identical_to_plain_on() {
    let a = run_skew(StealCfg::on(), FaultPlan::none());
    let b = run_skew(StealCfg::on().with_retry(0, 7), FaultPlan::none());
    assert_eq!(a, b, "retry_backoff == 0 must not change the schedule");
}

/// The headline scenario: the first victims always deny, retry re-arms
/// the thief, and the skewed load still migrates and completes.
#[test]
fn denied_first_attempts_retry_and_still_migrate() {
    let fp = run_skew(StealCfg::on().with_retry(10_000, 4), deny_first(3));
    assert_eq!(fp.tasks_completed, 65, "main + 64 work tasks despite forced denies");
    assert_eq!(fp.tasks_completed, fp.tasks_spawned);
    assert!(fp.steal_denies >= 3, "the forced denies must show up: {fp:?}");
    assert!(fp.tasks_stolen > 0, "retries must still reach a granting victim: {fp:?}");
}

/// Retry-enabled runs (with forced denies in the mix) are still a pure
/// function of the configuration: two runs replay bit-identically.
#[test]
fn retry_runs_replay_bit_identically() {
    let run = || run_skew(StealCfg::on().with_retry(10_000, 4), deny_first(3));
    let a = run();
    let b = run();
    assert_eq!(a, b, "retry + forced-deny run must replay bit-identically");
}

//! L3 hot-path benchmarks (wallclock) backing EXPERIMENTS.md Perf.
//!
//! Hand-rolled harness (criterion is not vendored): each case runs for a
//! fixed wall budget and reports ns/op plus, for whole-simulation cases,
//! *simulated events per host second* — the simulator's throughput metric
//! and the regression gate for the zero-allocation hot-path work (every
//! perf PR is judged against the numbers this emits).
//!
//! Every case is recorded into `BENCH_hotpath.json` next to the working
//! directory as `[{"case", "ns_per_op", "events_per_sec"}, ...]` so the
//! perf trajectory is machine-readable across PRs.
//!
//! Modes:
//!   cargo bench --bench hotpath              full run (~10 s)
//!   cargo bench --bench hotpath -- --smoke   1 iteration per case (CI:
//!                                            exercises the JSON emitter
//!                                            without burning minutes)

use std::time::Instant;

use myrmics::apps::jacobi;
use myrmics::apps::skew::{myrmics as skew_myrmics, SkewParams};
use myrmics::apps::synthetic::{empty_chain, hier_empty, independent, SynthParams};
use myrmics::apps::workload_api::workload;
use myrmics::config::{HierarchySpec, PlatformConfig, PolicyCfg, ShardCfg, StealCfg};
use myrmics::dep::node::DepNode;
use myrmics::experiments::bench::{run_myrmics, Scaling};
use myrmics::ids::{NodeId, RegionId, TaskId};
use myrmics::memory::trie::Trie;
use myrmics::mpi::runner::build_mpi;
use myrmics::platform::Platform;
use myrmics::sim::engine::Engine;
use myrmics::task::descriptor::Access;

struct Record {
    case: String,
    /// Engine shard count the case ran with (1 = legacy single queue).
    shards: usize,
    /// Host threads stepping the shards (1 = the sequential merge).
    threads: usize,
    ns_per_op: f64,
    events_per_sec: f64,
}

/// Run `f` repeatedly for `budget_ms` (at least once), where `f` returns
/// the number of ops it performed. Returns (ns/op, iterations).
fn time(label: &str, budget_ms: u128, out: &mut Vec<Record>, mut f: impl FnMut() -> u64) {
    // Warm up once, then measure — except in smoke mode (budget 0), where
    // each case must run exactly once.
    if budget_ms > 0 {
        let _ = f();
    }
    let start = Instant::now();
    let mut iters = 0u64;
    let mut work = 0u64;
    loop {
        work += f();
        iters += 1;
        if start.elapsed().as_millis() >= budget_ms {
            break;
        }
    }
    let elapsed = start.elapsed();
    let ns_per = elapsed.as_nanos() as f64 / work.max(1) as f64;
    println!(
        "{label:<44} {ns_per:>10.1} ns/op  ({iters} runs, {work} ops, {elapsed:.2?})"
    );
    out.push(Record {
        case: label.to_string(),
        shards: 1,
        threads: 1,
        ns_per_op: ns_per,
        events_per_sec: 0.0,
    });
}

/// Whole-simulation throughput case: run the engine-under-test for
/// `budget_ms` of host time, reporting simulated events per host second.
/// `build` returns a ready-to-run engine (a built `Platform`'s `.eng`, or
/// a pre-booted [`build_mpi`] engine) — only the event loop is timed;
/// construction cost is not part of the per-event metric the regression
/// gate is defined over.
fn sim_case(
    label: &'static str,
    budget_ms: u128,
    out: &mut Vec<Record>,
    build: impl FnMut() -> Engine,
) {
    sim_case_sharded(label, 1, 1, budget_ms, out, build)
}

/// [`sim_case`] with an explicit engine shard and thread count recorded
/// in the JSON row, so `tools/bench_delta.py` can group the scaling
/// ladder per `(shards, threads)` rung instead of seeing same-named
/// cases.
fn sim_case_sharded(
    label: &'static str,
    shards: usize,
    threads: usize,
    budget_ms: u128,
    out: &mut Vec<Record>,
    mut build: impl FnMut() -> Engine,
) {
    // Warm-up run (page in code, fill allocator pools) — skipped in smoke
    // mode (budget 0), where each case must run exactly once.
    if budget_ms > 0 {
        let mut eng = build();
        eng.run(Some(1 << 46));
    }
    let mut timed = std::time::Duration::ZERO;
    let mut events = 0u64;
    let mut runs = 0u32;
    loop {
        let mut eng = build();
        let t0 = Instant::now();
        eng.run(Some(1 << 46));
        timed += t0.elapsed();
        events += eng.world.gstats.events_processed;
        runs += 1;
        if timed.as_millis() >= budget_ms {
            break;
        }
    }
    let secs = timed.as_secs_f64();
    let eps = if secs > 0.0 { events as f64 / secs } else { 0.0 };
    let ns_per_event = if events > 0 { secs * 1e9 / events as f64 } else { 0.0 };
    println!(
        "{label:<44} {eps:>12.0} events/s ({runs} runs, {events} events, \
         {shards} shards x {threads} threads)"
    );
    out.push(Record {
        case: label.to_string(),
        shards,
        threads,
        ns_per_op: ns_per_event,
        events_per_sec: eps,
    });
}

fn emit_json(records: &[Record]) {
    let objs: Vec<String> = records
        .iter()
        .map(|r| {
            format!(
                "{{\"case\": \"{}\", \"shards\": {}, \"threads\": {}, \
                 \"ns_per_op\": {:.3}, \"events_per_sec\": {:.1}}}",
                r.case, r.shards, r.threads, r.ns_per_op, r.events_per_sec
            )
        })
        .collect();
    let s = myrmics::experiments::json_array(&objs);
    let path = "BENCH_hotpath.json";
    match std::fs::write(path, &s) {
        Ok(()) => println!("\nwrote {path} ({} cases)", records.len()),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("HOTPATH_SMOKE").is_ok();
    // In smoke mode each case runs exactly once (budget 0 => first
    // iteration already exceeds it).
    let micro_ms: u128 = if smoke { 0 } else { 600 };
    let sim_ms: u128 = if smoke { 0 } else { 1500 };
    let mut records: Vec<Record> = Vec::new();

    println!("== L3 hot paths ==");

    time("trie insert+get+remove (512 keys)", micro_ms, &mut records, || {
        let mut t = Trie::new();
        for k in 0..512u64 {
            t.insert(k * 7919 % 4096, k);
        }
        let mut acc = 0u64;
        for k in 0..512u64 {
            acc += t.get(k * 7919 % 4096).copied().unwrap_or(0);
        }
        for k in 0..512u64 {
            t.remove(k * 7919 % 4096);
        }
        std::hint::black_box(acc);
        1536
    });

    time("dep queue enqueue/grant/pop (64 entries)", micro_ms, &mut records, || {
        let anc = |_a: TaskId, _t: TaskId| false;
        let mut n = DepNode::new(NodeId::Region(RegionId(1)), None, 0);
        let mut actions = Vec::new();
        for i in 0..64 {
            n.enqueue(TaskId(i), 0, Access::Write, n.id, &anc);
        }
        let mut ops = 64;
        while !n.queue.is_empty() {
            actions.clear();
            n.collect_ready_into(&anc, &mut actions);
            ops += actions.len() as u64;
            let t = n.queue.front().unwrap().task;
            n.pop_task(t, 0);
            ops += 1;
        }
        ops
    });

    time("slab alloc/free cycle (256 objs)", micro_ms, &mut records, || {
        use myrmics::memory::addr::{GlobalPages, PagePool};
        use myrmics::memory::slab::SlabPool;
        let mut s = SlabPool::new();
        let mut p = PagePool::default();
        let mut g = GlobalPages::new();
        let mut addrs = Vec::with_capacity(256);
        for i in 0..256u64 {
            addrs.push(s.alloc(64 + (i % 7) * 64, &mut p, &mut g));
        }
        for a in addrs {
            s.free(a, &mut p);
        }
        512
    });

    // The placement seam itself: one 8-way child choice per op, per
    // policy, over a 16-range pack. Keeps the policy layer's dispatch +
    // dense-table cost visible in BENCH_hotpath.json. Hierarchy and pack
    // are built once outside the timed closure so the measurement is the
    // choice path, not construction.
    {
        use myrmics::noc::msg::ProducerRange;
        use myrmics::sched::hierarchy::HierarchyMap;
        use myrmics::sched::policy::Placer;
        let hier = HierarchyMap::build(64, &HierarchySpec::two_level(8));
        let pack: Vec<ProducerRange> = (0..16)
            .map(|i| ProducerRange {
                producer: hier.subtree_workers(hier.children[0][i % 8])[i / 8],
                addr: (i as u64) * 4096,
                bytes: 4096,
            })
            .collect();
        for cfg in [
            PolicyCfg::locality_balance(10),
            PolicyCfg::round_robin(),
            PolicyCfg::power_of_two(),
        ] {
            let label = format!("place choose 8-way ({})", cfg.name());
            let mut placer = Placer::new(&cfg, &hier, 0, 42);
            time(&label, micro_ms, &mut records, || {
                for _ in 0..256 {
                    let (c, _) = placer.choose_child(&hier, 0, &pack);
                    std::hint::black_box(c);
                }
                256
            });
        }
    }

    // The ready-queue layer work stealing migrates through: push (enqueue
    // ready), pop-front (dispatch) and pop-back (steal) on one queue,
    // built outside the closure so the timed path is pure steady-state
    // slot reuse — zero allocation after the first iteration's warm-up.
    {
        use myrmics::sched::readyq::ReadyQ;
        let mut q = ReadyQ::new();
        time("readyq push/pop/migrate (256 tasks)", micro_ms, &mut records, move || {
            for i in 0..256u64 {
                q.push_back(TaskId(i));
            }
            for _ in 0..128 {
                std::hint::black_box(q.pop_front());
                std::hint::black_box(q.pop_back());
            }
            512
        });
    }

    time("next_hop traversal (depth-4 tree)", micro_ms, &mut records, || {
        use myrmics::config::HierarchySpec;
        use myrmics::memory::region::Memory;
        use myrmics::sched::hierarchy::HierarchyMap;
        let h = HierarchyMap::build(8, &HierarchySpec::flat());
        let mut m = Memory::new(h.n_scheds);
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let b = m.ralloc(a, 0, &h);
        let c = m.ralloc(b, 0, &h);
        let o = m.alloc(64, c);
        let target = NodeId::Object(o);
        let mut ops = 0u64;
        for _ in 0..256 {
            let mut at = NodeId::Region(a);
            while at != target {
                at = m.next_hop(at, target).expect("descends");
                ops += 1;
            }
            std::hint::black_box(at);
        }
        ops
    });

    println!("\n== whole-simulation throughput (events / host second) ==");
    // Fig-7a shape: serialized empty tasks through one scheduler — the
    // purest per-task runtime-overhead path (spawn, dep, pack, place,
    // dispatch, done with no parallelism to hide behind).
    sim_case("fig7a empty chain 1w x 1000 tasks", sim_ms, &mut records, || {
        let (reg, main) = empty_chain();
        Platform::build_with(PlatformConfig::flat(1), reg, main, |w| {
            w.app = Some(Box::new(SynthParams { n_tasks: 1000, ..Default::default() }));
        })
        .eng
    });
    // Fig-7b shape: independent tasks over a scheduler hierarchy — the
    // throughput case the ≥25%-per-PR target tracks.
    sim_case("fig7 independent 64w x 512 tasks", sim_ms, &mut records, || {
        let (reg, main) = independent();
        Platform::build_with(PlatformConfig::hierarchical(64), reg, main, |w| {
            w.app = Some(Box::new(SynthParams {
                n_tasks: 512,
                task_cycles: 1_000_000,
                ..Default::default()
            }));
        })
        .eng
    });
    sim_case("fig7 independent 256w x 1024 tasks", sim_ms, &mut records, || {
        let (reg, main) = independent();
        Platform::build_with(PlatformConfig::hierarchical(256), reg, main, |w| {
            w.app = Some(Box::new(SynthParams {
                n_tasks: 1024,
                task_cycles: 1_000_000,
                ..Default::default()
            }));
        })
        .eng
    });
    // Shard/thread scaling ladder: the same 256-worker fig7 shape across
    // `(shards, threads)` rungs. Same label, distinguished by the
    // `shards`/`threads` JSON fields. The schedule is bit-identical by
    // contract, so event counts match across rungs: the `threads=1` rows
    // isolate the engine's sequential merge overhead, and the
    // `threads>1` rows measure the real host-thread speedup of the
    // windowed executor (see docs/sim-engine.md "Sharded engine").
    for (shards, threads) in [(1usize, 1usize), (2, 1), (2, 2), (4, 1), (4, 2), (4, 4)] {
        sim_case_sharded(
            "fig7 independent 256w x 1024 tasks (shard scaling)",
            shards,
            threads,
            sim_ms,
            &mut records,
            move || {
                let (reg, main) = independent();
                let mut cfg = PlatformConfig::hierarchical(256);
                cfg.shard = ShardCfg::with_threads(shards, threads);
                Platform::build_with(cfg, reg, main, |w| {
                    // fig7-independent satisfies the single-spawner
                    // contract, so the threaded rungs actually take the
                    // windowed executor instead of silently falling back.
                    w.par_safe = true;
                    w.app = Some(Box::new(SynthParams {
                        n_tasks: 1024,
                        task_cycles: 1_000_000,
                        ..Default::default()
                    }));
                })
                .eng
            },
        );
    }
    // The same fig7 throughput shape under the non-default placement
    // policies: whole-simulation policy cost (and any schedule-quality
    // effect on event counts) lands in BENCH_hotpath.json next to the
    // default-policy case above.
    for (label, policy) in [
        ("fig7 independent 64w x 512 tasks (rr)", PolicyCfg::round_robin()),
        ("fig7 independent 64w x 512 tasks (p2c)", PolicyCfg::power_of_two()),
    ] {
        sim_case(label, sim_ms, &mut records, move || {
            let (reg, main) = independent();
            let mut cfg = PlatformConfig::hierarchical(64);
            cfg.policy = policy;
            Platform::build_with(cfg, reg, main, |w| {
                w.app = Some(Box::new(SynthParams {
                    n_tasks: 512,
                    task_cycles: 1_000_000,
                    ..Default::default()
                }));
            })
            .eng
        });
    }
    // The fig7 throughput shape with work stealing enabled: the ReadyQ
    // dispatch path runs throttled (headroom checks, queue churn) and the
    // steal protocol's request/deny chatter rides along — its whole-sim
    // cost lands next to the default-policy case above.
    sim_case("fig7 independent 64w x 512 tasks (steal)", sim_ms, &mut records, || {
        let (reg, main) = independent();
        let mut cfg = PlatformConfig::hierarchical(64);
        cfg.policy.steal = StealCfg::on();
        Platform::build_with(cfg, reg, main, |w| {
            w.app = Some(Box::new(SynthParams {
                n_tasks: 512,
                task_cycles: 1_000_000,
                ..Default::default()
            }));
        })
        .eng
    });
    // The skewed-spawn adversary with stealing on: grants actually fire,
    // so migration (pop-back, re-place, ScheduleDown) is exercised at
    // whole-simulation scale.
    sim_case("skew 64w x 256 tasks (steal)", sim_ms, &mut records, || {
        let (reg, main) = skew_myrmics();
        let mut cfg = PlatformConfig::hierarchical(64);
        cfg.policy.steal = StealCfg::on();
        Platform::build_with(cfg, reg, main, |w| {
            w.app = Some(Box::new(SkewParams {
                tasks: 256,
                task_cycles: 500_000,
                hot_pct: 90,
                groups: 4,
            }));
        })
        .eng
    });
    // Fig-8/12b shape: nested regions over a *deep* (3-level) scheduler
    // tree — spawns, grants and quiescence all hop-forward along the tree,
    // exercising the routed-message path and the per-sender channel tables
    // rather than the flat fig7 fan-out. Geometry mirrors fig12's VI-E
    // setup (fanout 6: 64 workers -> 11 leaves under 2 mids, one domain
    // region per leaf-level scheduler).
    sim_case("fig8 hier_empty 64w deep tree (3 lvls)", sim_ms, &mut records, || {
        let (reg, main) = hier_empty();
        let cfg = PlatformConfig::new(
            64,
            HierarchySpec { scheds_per_level: vec![1, 2, 11] },
        );
        Platform::build_with(cfg, reg, main, |w| {
            w.app = Some(Box::new(SynthParams {
                domains: 11,
                per_domain: 8,
                domain_level: 2,
                task_cycles: 100_000,
                ..Default::default()
            }));
        })
        .eng
    });
    // MPI baseline: the rank runner's send/recv/collective machinery over
    // the same event core (DMA-delivered payloads, no credit channels).
    sim_case("mpi jacobi 64 ranks x 6 iters", sim_ms, &mut records, || {
        let p = jacobi::JacobiParams::modeled(8192, 6, 128, 1);
        build_mpi(jacobi::mpi_programs(&p, 64), &PlatformConfig::flat(1))
    });

    if !smoke {
        println!("\n== end-to-end benchmark sims (host wall time) ==");
        for (bench, w) in
            [(workload("jacobi"), 128), (workload("bitonic"), 128), (workload("kmeans"), 128)]
        {
            let start = Instant::now();
            let (t, eng) = run_myrmics(bench, w, Scaling::Strong, true, None);
            let wall = start.elapsed();
            println!(
                "{:<20} {w:>4} workers: sim {:>12} cycles, {:>8} events, host {:.2?}",
                bench.name(),
                t,
                eng.world.gstats.events_processed,
                wall
            );
        }
    }

    emit_json(&records);
}

//! L3 hot-path microbenchmarks (wallclock) backing EXPERIMENTS.md Perf.
//!
//! Hand-rolled harness (criterion is not vendored): each case runs for a
//! fixed wall budget and reports ns/op plus, for whole-simulation cases,
//! simulated events per host second — the simulator's throughput metric.

use std::time::Instant;

use myrmics::apps::synthetic::{independent, SynthParams};
use myrmics::config::PlatformConfig;
use myrmics::dep::node::DepNode;
use myrmics::experiments::bench::{run_myrmics, BenchKind, Scaling};
use myrmics::ids::{NodeId, RegionId, TaskId};
use myrmics::memory::trie::Trie;
use myrmics::platform::Platform;
use myrmics::task::descriptor::Access;

fn time<F: FnMut() -> u64>(label: &str, mut f: F) {
    // Warm up once, then measure.
    let _ = f();
    let start = Instant::now();
    let mut iters = 0u64;
    let mut work = 0u64;
    while start.elapsed().as_millis() < 600 {
        work += f();
        iters += 1;
    }
    let elapsed = start.elapsed();
    let ns_per = elapsed.as_nanos() as f64 / work.max(1) as f64;
    println!(
        "{label:<44} {:>10.1} ns/op  ({iters} runs, {work} ops, {:.2?})",
        ns_per, elapsed
    );
}

fn main() {
    println!("== L3 hot paths ==");

    time("trie insert+get+remove (512 keys)", || {
        let mut t = Trie::new();
        for k in 0..512u64 {
            t.insert(k * 7919 % 4096, k);
        }
        let mut acc = 0u64;
        for k in 0..512u64 {
            acc += t.get(k * 7919 % 4096).copied().unwrap_or(0);
        }
        for k in 0..512u64 {
            t.remove(k * 7919 % 4096);
        }
        std::hint::black_box(acc);
        1536
    });

    time("dep queue enqueue/grant/pop (64 entries)", || {
        let anc = |_a: TaskId, _t: TaskId| false;
        let mut n = DepNode::new(NodeId::Region(RegionId(1)), None, 0);
        for i in 0..64 {
            n.enqueue(TaskId(i), 0, Access::Write, n.id, &anc);
        }
        let mut ops = 64;
        while !n.queue.is_empty() {
            let acts = n.collect_ready(&anc);
            ops += acts.len() as u64;
            let t = n.queue.front().unwrap().task;
            n.pop_task(t, 0);
            ops += 1;
        }
        ops
    });

    time("slab alloc/free cycle (256 objs)", || {
        use myrmics::memory::addr::{GlobalPages, PagePool};
        use myrmics::memory::slab::SlabPool;
        let mut s = SlabPool::new();
        let mut p = PagePool::default();
        let mut g = GlobalPages::new();
        let mut addrs = Vec::with_capacity(256);
        for i in 0..256u64 {
            addrs.push(s.alloc(64 + (i % 7) * 64, &mut p, &mut g));
        }
        for a in addrs {
            s.free(a, &mut p);
        }
        512
    });

    println!("\n== whole-simulation throughput (events / host second) ==");
    for (label, workers, tasks) in
        [("independent 64w x 512 tasks", 64usize, 512usize), ("independent 256w x 1024", 256, 1024)]
    {
        let start = Instant::now();
        let mut events = 0u64;
        let mut runs = 0u32;
        while start.elapsed().as_millis() < 1500 {
            let (reg, main) = independent();
            let mut plat =
                Platform::build_with(PlatformConfig::hierarchical(workers), reg, main, |w| {
                    w.app = Some(Box::new(SynthParams {
                        n_tasks: tasks,
                        task_cycles: 1_000_000,
                        ..Default::default()
                    }));
                });
            plat.run(Some(1 << 46));
            events += plat.world().gstats.events_processed;
            runs += 1;
        }
        let eps = events as f64 / start.elapsed().as_secs_f64();
        println!("{label:<44} {eps:>12.0} events/s ({runs} runs)");
    }

    println!("\n== end-to-end benchmark sims (host wall time) ==");
    for (bench, w) in [(BenchKind::Jacobi, 128), (BenchKind::Bitonic, 128), (BenchKind::Kmeans, 128)]
    {
        let start = Instant::now();
        let (t, eng) = run_myrmics(bench, w, Scaling::Strong, true, None);
        let wall = start.elapsed();
        println!(
            "{:<20} {w:>4} workers: sim {:>12} cycles, {:>8} events, host {:.2?}",
            bench.name(),
            t,
            eng.world.gstats.events_processed,
            wall
        );
    }
}

//! Regenerate every table and figure of the paper's evaluation (VI).
//!
//! Run everything:   `cargo bench --bench figures`
//! One experiment:   `cargo bench --bench figures -- fig8-strong`
//! Reduced sweep:    `cargo bench --bench figures -- --quick`

fn main() {
    let args: Vec<String> =
        std::env::args().skip(1).filter(|a| !a.starts_with("--bench")).collect();
    myrmics::experiments::cli::run(&args);
}

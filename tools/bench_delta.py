#!/usr/bin/env python3
"""Print events/sec deltas between a BENCH_hotpath.json run and the
baseline recorded in ROADMAP.md.

Non-blocking CI aid (the workflow runs it with continue-on-error): it
surfaces the per-case throughput trajectory next to every PR without
gating merges on a noisy shared runner.

Rows are keyed by (case, shards, threads): the sharded-engine scaling
ladder reuses one case label across (shard, thread) rungs and is
distinguished by the "shards"/"threads" fields (absent in older records,
which default to 1 — pre-shard and pre-thread baselines keep matching).

Baseline format inside ROADMAP.md — an HTML comment block so the numbers
live next to the prose that explains them:

    <!-- hotpath-baseline
    [{"case": "...", "shards": 1, "threads": 1, "events_per_sec": 123.0}, ...]
    -->

Usage: bench_delta.py BENCH_hotpath.json ROADMAP.md
"""

import json
import re
import sys


def key(r):
    return (r["case"], int(r.get("shards", 1)), int(r.get("threads", 1)))


def label(k):
    case, shards, threads = k
    if shards == 1 and threads == 1:
        return case
    return f"{case} [{shards} shards x {threads} thr]"


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__.strip())
        return 2
    bench_path, roadmap_path = sys.argv[1], sys.argv[2]
    with open(bench_path) as f:
        bench = {key(r): r for r in json.load(f)}
    with open(roadmap_path) as f:
        text = f.read()
    m = re.search(r"<!--\s*hotpath-baseline\s*\n(.*?)-->", text, re.S)
    if not m:
        print("no hotpath-baseline block in ROADMAP.md; nothing to compare")
        return 0
    try:
        baseline = {key(r): r for r in json.loads(m.group(1))}
    except json.JSONDecodeError as e:
        print(f"unparseable hotpath-baseline block: {e}")
        return 0
    if not baseline:
        print("hotpath-baseline block is empty (no machine has recorded numbers yet)")
        return 0
    print(f"{'case':<56} {'baseline':>12} {'current':>12} {'delta':>8}")
    for k, b in baseline.items():
        name = label(k)
        base = b.get("events_per_sec", 0.0)
        cur = bench.get(k, {}).get("events_per_sec", 0.0)
        if not cur:
            print(f"{name:<56} {base:>12.0f} {'missing':>12} {'-':>8}")
            continue
        if base:
            print(f"{name:<56} {base:>12.0f} {cur:>12.0f} {100.0 * (cur / base - 1.0):>+7.1f}%")
        else:
            print(f"{name:<56} {base:>12.0f} {cur:>12.0f} {'-':>8}")
    for k, r in bench.items():
        if k not in baseline and r.get("events_per_sec"):
            print(f"{label(k):<56} (new case, no baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Layer-1 Pallas kernel: tile matmul-accumulate.

The benchmark's hottest kernel: C_tile += A_tile @ B_tile. TPU mapping:
the (s, s) tiles target the MXU systolic array (s a multiple of the
128-lane tiling on real hardware; 16 here to keep the AOT artifact small);
all three tiles live in VMEM for the whole block. `interpret=True` for the
CPU PJRT plugin.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(a_ref, b_ref, c_ref, o_ref):
    o_ref[...] = c_ref[...] + jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@jax.jit
def matmul_tile(a, b, c):
    """a: (m, k), b: (k, n), c: (m, n) f32 -> c + a @ b."""
    return pl.pallas_call(
        _mm_kernel,
        out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
        interpret=True,
    )(a, b, c)


def mxu_utilization(m: int, k: int, n: int, mxu: int = 128) -> float:
    """Estimated MXU lane utilization for an (m,k,n) tile on a real TPU:
    fraction of the 128x128 systolic array the tile fills per pass."""
    return min(1.0, m / mxu) * min(1.0, n / mxu)


def vmem_bytes(m: int, k: int, n: int, itemsize: int = 4) -> int:
    return (m * k + k * n + 2 * m * n) * itemsize

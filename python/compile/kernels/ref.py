"""Pure-jnp correctness oracles for the Pallas kernels (Layer 1).

Every kernel in this package is checked against these references by
``python/tests/test_kernels.py`` (exact shapes + hypothesis sweeps). The
semantics deliberately match the rust fallback implementations in
``rust/src/apps/*.rs`` so that kernel-path and fallback-path runs of the
simulator produce identical numerics.
"""

import jax.numpy as jnp


def jacobi_band(x):
    """One Jacobi sweep over a band with halo.

    x: (rows + 2, n) f32 — band rows plus one halo row above and below.
    Returns (rows, n): for each interior output cell the 4-neighbour mean;
    the j-edges use clamped indexing (they are overwritten by the caller's
    fixed-border logic, but must match the rust fallback bit-for-bit).
    """
    up = x[:-2, :]
    down = x[2:, :]
    mid = x[1:-1, :]
    left = jnp.concatenate([mid[:, :1], mid[:, :-1]], axis=1)
    right = jnp.concatenate([mid[:, 1:], mid[:, -1:]], axis=1)
    return 0.25 * (up + down + left + right)


def matmul_tile(a, b, c):
    """Tile accumulate: c + a @ b (all (s, s) f32)."""
    return c + a @ b


def kmeans_assign(pts, cents):
    """Nearest-centroid partial sums.

    pts: (P, 3) f32; cents: (K, 3) f32.
    Returns (K, 4): per-cluster [sum_x, sum_y, sum_z, count].
    """
    d2 = ((pts[:, None, :] - cents[None, :, :]) ** 2).sum(-1)  # (P, K)
    best = jnp.argmin(d2, axis=1)  # (P,)
    k = cents.shape[0]
    onehot = (best[:, None] == jnp.arange(k)[None, :]).astype(pts.dtype)  # (P, K)
    sums = onehot.T @ pts  # (K, 3)
    counts = onehot.sum(axis=0)[:, None]  # (K, 1)
    return jnp.concatenate([sums, counts], axis=1)


def bitonic_merge(a, b, asc):
    """Merge-split of two sorted runs (each (m,) f32).

    Returns (low, high) halves of the merged sequence; `asc` selects which
    buffer keeps the low half (static python bool).
    """
    both = jnp.sort(jnp.concatenate([a, b]))
    m = a.shape[0]
    lo, hi = both[:m], both[m:]
    if asc:
        return lo, hi
    return hi, lo

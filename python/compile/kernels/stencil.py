"""Layer-1 Pallas kernel: Jacobi band sweep.

TPU mapping (DESIGN.md Hardware-Adaptation): the band (rows+2, n) block is
one VMEM-resident tile; the sweep is pure VPU elementwise work (shifted
adds), so the BlockSpec keeps the whole halo'd band in one block and the
grid iterates over bands. `interpret=True` is mandatory on the CPU PJRT
plugin — real-TPU lowering emits a Mosaic custom call the CPU client
cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(x_ref, o_ref):
    x = x_ref[...]
    up = x[:-2, :]
    down = x[2:, :]
    mid = x[1:-1, :]
    left = jnp.concatenate([mid[:, :1], mid[:, :-1]], axis=1)
    right = jnp.concatenate([mid[:, 1:], mid[:, -1:]], axis=1)
    o_ref[...] = 0.25 * (up + down + left + right)


@functools.partial(jax.jit, static_argnames=())
def jacobi_band(x):
    """x: (rows + 2, n) f32 -> (rows, n) f32."""
    rows = x.shape[0] - 2
    n = x.shape[1]
    return pl.pallas_call(
        _jacobi_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=True,
    )(x)


def vmem_bytes(rows: int, n: int, itemsize: int = 4) -> int:
    """VMEM footprint estimate: input block + output block."""
    return (rows + 2) * n * itemsize + rows * n * itemsize

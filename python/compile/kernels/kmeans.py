"""Layer-1 Pallas kernel: k-means assignment + partial reduction.

One task's points block (P, 3) against the K centroids, producing the
(K, 4) partial [sum_xyz, count] buffer that the hierarchical reduction
tasks combine (paper VI-B: "K-Means Clustering features parallel
reductions and broadcasts"). TPU mapping: distance matrix (P, K) via
broadcast-subtract on the VPU, the one-hot partial reduction as an MXU
matmul (K x P @ P x 3). `interpret=True` for the CPU PJRT plugin.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(pts_ref, cents_ref, o_ref):
    pts = pts_ref[...]
    cents = cents_ref[...]
    d2 = ((pts[:, None, :] - cents[None, :, :]) ** 2).sum(-1)
    best = jnp.argmin(d2, axis=1)
    k = cents.shape[0]
    onehot = (best[:, None] == jnp.arange(k)[None, :]).astype(pts.dtype)
    sums = jnp.dot(onehot.T, pts, preferred_element_type=jnp.float32)
    counts = onehot.sum(axis=0)[:, None]
    o_ref[...] = jnp.concatenate([sums, counts], axis=1)


@jax.jit
def kmeans_assign(pts, cents):
    """pts: (P, 3) f32, cents: (K, 3) f32 -> (K, 4) partial sums."""
    k = cents.shape[0]
    return pl.pallas_call(
        _assign_kernel,
        out_shape=jax.ShapeDtypeStruct((k, 4), pts.dtype),
        interpret=True,
    )(pts, cents)


def vmem_bytes(p: int, k: int, itemsize: int = 4) -> int:
    # points + centroids + distance matrix + one-hot + output.
    return (p * 3 + k * 3 + p * k * 2 + k * 4) * itemsize

"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for the rust
coordinator.

HLO *text* (not a serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. Lowered with
``return_tuple=True`` — the rust side unwraps with ``to_tuple``.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
Shapes must match ``rust/src/runtime/shapes.rs``.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Fixed AOT shapes — keep in sync with rust/src/runtime/shapes.rs.
JACOBI_IN = (10, 32)  # (rows + 2, n)
JACOBI_X2_IN = (12, 32)  # (rows + 4, n)
MATMUL_TILE = (16, 16, 16)
KMEANS_POINTS = 256
KMEANS_K = 4

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def kernels():
    """(name, jitted fn, example args) for every artifact."""
    m, k, n = MATMUL_TILE
    return [
        ("jacobi_band", model.jacobi_band, (spec(*JACOBI_IN),)),
        ("jacobi_band_x2", model.jacobi_band_x2, (spec(*JACOBI_X2_IN),)),
        ("matmul_tile", model.matmul_tile, (spec(m, k), spec(k, n), spec(m, n))),
        ("kmeans_assign", model.kmeans_assign, (spec(KMEANS_POINTS, 3), spec(KMEANS_K, 3))),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, fn, specs in kernels():
        lowered = fn.lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {name}: {len(text)} chars -> {path}")


if __name__ == "__main__":
    main()

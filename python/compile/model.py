"""Layer 2: JAX compute graphs composed from the Layer-1 Pallas kernels.

These are the task-body computations the rust coordinator executes through
PJRT. Each function is jitted and AOT-lowered by ``aot.py`` with the fixed
shapes in ``rust/src/runtime/shapes.rs``. Composition happens here — e.g.
the fused multi-sweep Jacobi variant (`jacobi_band_x2`) chains two kernel
invocations inside one executable so XLA can fuse the intermediate away
(the L2 optimization recorded in EXPERIMENTS.md Perf).
"""

import jax
import jax.numpy as jnp

from compile.kernels import kmeans as _kmeans
from compile.kernels import matmul as _matmul
from compile.kernels import stencil as _stencil


@jax.jit
def jacobi_band(x):
    """One band sweep: (rows + 2, n) -> (rows, n)."""
    return (_stencil.jacobi_band(x),)


@jax.jit
def jacobi_band_x2(x):
    """Two fused sweeps over one band (requires a 2-deep halo):
    (rows + 4, n) -> (rows, n). XLA fuses the intermediate band away,
    halving HBM round trips per output row on real hardware."""
    mid = _stencil.jacobi_band(x)  # (rows + 2, n)
    return (_stencil.jacobi_band(mid),)


@jax.jit
def matmul_tile(a, b, c):
    """C-tile accumulate."""
    return (_matmul.matmul_tile(a, b, c),)


@jax.jit
def kmeans_assign(pts, cents):
    """Assignment + partial reduction for one point band."""
    return (_kmeans.kmeans_assign(pts, cents),)


def donate_hint():
    """Buffer-donation note: on real hardware the Jacobi A/B buffers are
    donated between sweeps (jax.jit(..., donate_argnums=0)); the CPU PJRT
    used for correctness ignores donation, so we keep the default here and
    document the intent."""
    return 0

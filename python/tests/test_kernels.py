"""Kernel-vs-oracle correctness: the core L1 signal.

Fixed-shape checks at the exact AOT shapes, plus hypothesis sweeps over
shapes and value ranges (the Pallas kernels are shape-polymorphic under
interpret=True even though the AOT artifacts freeze one shape).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot
from compile.kernels import kmeans as kmeans_k
from compile.kernels import matmul as matmul_k
from compile.kernels import ref
from compile.kernels import stencil

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, lo=-1.0, hi=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(np.float32))


# ------------------------------------------------------------- fixed shapes


def test_jacobi_fixed_shape_matches_ref():
    x = rand(aot.JACOBI_IN, 1)
    got = stencil.jacobi_band(x)
    want = ref.jacobi_band(x)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert got.shape == (aot.JACOBI_IN[0] - 2, aot.JACOBI_IN[1])


def test_matmul_fixed_shape_matches_ref():
    m, k, n = aot.MATMUL_TILE
    a, b, c = rand((m, k), 2), rand((k, n), 3), rand((m, n), 4)
    np.testing.assert_allclose(
        matmul_k.matmul_tile(a, b, c), ref.matmul_tile(a, b, c), rtol=1e-5, atol=1e-5
    )


def test_kmeans_fixed_shape_matches_ref():
    pts = rand((aot.KMEANS_POINTS, 3), 5, 0.0, 10.0)
    cents = rand((aot.KMEANS_K, 3), 6, 0.0, 10.0)
    got = kmeans_k.kmeans_assign(pts, cents)
    want = ref.kmeans_assign(pts, cents)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # Counts sum to P.
    assert float(got[:, 3].sum()) == aot.KMEANS_POINTS


# --------------------------------------------------------- hypothesis sweeps


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=24),
    n=st.integers(min_value=2, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_jacobi_shape_sweep(rows, n, seed):
    x = rand((rows + 2, n), seed, -100.0, 100.0)
    np.testing.assert_allclose(stencil.jacobi_band(x), ref.jacobi_band(x), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=32),
    k=st.integers(min_value=1, max_value=32),
    n=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_matmul_shape_sweep(m, k, n, seed):
    a, b, c = rand((m, k), seed), rand((k, n), seed + 1), rand((m, n), seed + 2)
    np.testing.assert_allclose(
        matmul_k.matmul_tile(a, b, c), ref.matmul_tile(a, b, c), rtol=1e-4, atol=1e-5
    )


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=200),
    k=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kmeans_shape_sweep(p, k, seed):
    pts = rand((p, 3), seed, 0.0, 50.0)
    cents = rand((k, 3), seed + 1, 0.0, 50.0)
    got = kmeans_k.kmeans_assign(pts, cents)
    want = ref.kmeans_assign(pts, cents)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- L2 composition


def test_fused_x2_equals_two_single_sweeps():
    from compile import model

    x = rand(aot.JACOBI_X2_IN, 9)
    (fused,) = model.jacobi_band_x2(x)
    step1 = ref.jacobi_band(x)
    step2 = ref.jacobi_band(step1)
    np.testing.assert_allclose(fused, step2, rtol=1e-6)


def test_kmeans_partials_reduce_to_global():
    # Partial buffers from two bands sum to the whole-set partials —
    # the invariant the hierarchical reduction relies on.
    pts = rand((128, 3), 11, 0.0, 10.0)
    cents = rand((4, 3), 12, 0.0, 10.0)
    whole = ref.kmeans_assign(pts, cents)
    p1 = kmeans_k.kmeans_assign(pts[:64], cents)
    p2 = kmeans_k.kmeans_assign(pts[64:], cents)
    np.testing.assert_allclose(p1 + p2, whole, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- bitonic ref


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31),
    asc=st.booleans(),
)
def test_bitonic_merge_partitions(m, seed, asc):
    rng = np.random.default_rng(seed)
    a = jnp.sort(jnp.asarray(rng.uniform(0, 1, m).astype(np.float32)))
    b = jnp.sort(jnp.asarray(rng.uniform(0, 1, m).astype(np.float32)))
    lo_or_hi, other = ref.bitonic_merge(a, b, asc)
    merged = np.sort(np.concatenate([a, b]))
    if asc:
        np.testing.assert_array_equal(lo_or_hi, merged[:m])
        np.testing.assert_array_equal(other, merged[m:])
    else:
        np.testing.assert_array_equal(lo_or_hi, merged[m:])
        np.testing.assert_array_equal(other, merged[:m])


# --------------------------------------------------------------- AOT plumbing


def test_hlo_text_generation():
    # Every artifact lowers to parseable, non-trivial HLO text.
    for name, fn, specs in aot.kernels():
        lowered = fn.lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text, name
        assert len(text) > 200, name


@pytest.mark.parametrize("name", ["jacobi_band", "matmul_tile", "kmeans_assign"])
def test_aot_shapes_match_rust_constants(name):
    # Guard against shape drift between aot.py and rust/src/runtime/shapes.rs.
    rust = open("../rust/src/runtime/shapes.rs").read()
    if name == "jacobi_band":
        rows, n = aot.JACOBI_IN
        assert f"JACOBI_IN: (usize, usize) = ({rows}, {n})" in rust
    elif name == "matmul_tile":
        m, k, n = aot.MATMUL_TILE
        assert f"MATMUL_TILE: (usize, usize, usize) = ({m}, {k}, {n})" in rust
    else:
        assert f"KMEANS_POINTS: usize = {aot.KMEANS_POINTS}" in rust
        assert f"KMEANS_K: usize = {aot.KMEANS_K}" in rust

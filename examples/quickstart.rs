//! Quickstart: write a Myrmics application against the Fig-4 API and run
//! it on the simulated heterogeneous manycore.
//!
//!     cargo run --release --example quickstart
//!
//! The app: allocate a region with 8 data objects, spawn one `fill` task
//! per object (parallel writers), then one `sum` task reading the whole
//! region (the runtime orders it after every producer), and check the
//! result.

use myrmics::config::PlatformConfig;
use myrmics::ids::RegionId;
use myrmics::platform::Platform;
use myrmics::task::descriptor::TaskArg;
use myrmics::task::registry::Registry;

fn main() {
    let mut reg = Registry::new();

    // Task bodies are plain Rust over the TaskCtx API (sys_alloc,
    // sys_spawn, ... — see api::ctx). `compute` models task cycles.
    let fill = reg.register("fill", |ctx| {
        let o = ctx.obj_arg(0);
        let i = ctx.val_arg(1);
        ctx.compute(500_000);
        ctx.write_f32(o, &[i as f32; 16]);
    });

    let sum = reg.register("sum", |ctx| {
        ctx.compute(200_000);
        let total: f32 = (1..ctx.n_args())
            .map(|a| ctx.read_f32(ctx.obj_arg(a)).iter().sum::<f32>())
            .sum();
        println!("sum task sees total = {total} (expect 448 = 16 * (0+..+7))");
        assert_eq!(total, 448.0);
    });

    let main_fn = reg.register("main", move |ctx| {
        // sys_ralloc: a region for the dataset (level hint 1 places it on
        // a leaf scheduler).
        let r = ctx.ralloc(RegionId::ROOT, 1);
        // sys_balloc: 8 packed objects.
        let objs = ctx.balloc(64, r, 8);
        for (i, &o) in objs.iter().enumerate() {
            ctx.spawn(fill, vec![TaskArg::obj_out(o), TaskArg::val(i as u64)]);
        }
        // The reduction depends on the whole region: it runs only after
        // every fill finished (dependency queues + child counters).
        let mut args = vec![TaskArg::region_in(r).notransfer()];
        args.extend(objs.iter().map(|&o| TaskArg::obj_in(o)));
        ctx.spawn(sum, args);
    });

    // 16 workers, 1 top + leaf schedulers, paper cost model.
    let cfg = PlatformConfig::hierarchical(16);
    let mut platform = Platform::build(cfg, reg, main_fn);
    let cycles = platform.run(Some(1 << 40));

    let w = platform.world();
    println!(
        "completed {} tasks in {} simulated MicroBlaze cycles ({} NoC messages, {} DMA bytes)",
        w.gstats.tasks_completed,
        cycles,
        w.gstats.msgs_total,
        platform.eng.sim.stats.iter().map(|s| s.dma_bytes_in).sum::<u64>(),
    );
    assert_eq!(w.gstats.tasks_completed, 10);
    println!("quickstart OK");
}

//! Irregular-parallelism demo: Barnes-Hut with per-iteration region trees.
//!
//!     cargo run --release --example barnes_hut_demo [workers]
//!
//! Shows the features the paper motivates regions with: dynamic
//! allocation of whole subtrees per loop repetition, `sys_rfree` tearing
//! them down while the dependency metadata drains, `sys_wait` driving the
//! iteration loop, and tasks operating on *pairs* of regions.

use myrmics::apps::barnes_hut::{myrmics, BhParams};
use myrmics::config::PlatformConfig;
use myrmics::experiments::summarize;
use myrmics::platform::Platform;

fn main() {
    let workers: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(64);
    let p = BhParams { bodies: 1 << 18, bands: 2 * workers, groups: 4.min(workers), iters: 4 };
    println!(
        "Barnes-Hut: {} bodies, {} bands, {} iterations on {} workers (hierarchical)",
        p.bodies, p.bands, p.iters, workers
    );
    let (reg, main) = myrmics();
    let mut plat = Platform::build_with(PlatformConfig::hierarchical(workers), reg, main, |w| {
        w.app = Some(Box::new(p));
    });
    let t = plat.run(Some(1 << 46));
    let s = summarize(&plat.eng, t);
    let w = plat.world();
    println!("finished in {} cycles", t);
    println!(
        "tasks: {} | regions created: {} | live at exit: {} (trees freed each iteration)",
        w.gstats.tasks_completed,
        w.gstats.regions_created,
        w.mem.n_regions()
    );
    println!(
        "worker time: {:.0}% task / {:.0}% runtime / {:.0}% idle | sched busy {:.1}%",
        100.0 * s.worker_task_frac,
        100.0 * s.worker_runtime_frac,
        100.0 * s.worker_idle_frac,
        100.0 * s.sched_busy_frac
    );
    println!(
        "traffic per worker: {} msgs, {} DMA | dep boundary msgs: {}",
        myrmics::experiments::fmt_bytes(s.per_worker_msg_bytes),
        myrmics::experiments::fmt_bytes(s.per_worker_dma_bytes),
        w.gstats.dep_boundary_msgs
    );
    assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
    println!("barnes_hut_demo OK");
}

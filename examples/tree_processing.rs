//! The paper's Fig-1 example: hierarchically process, then print, a
//! binary tree of regions.
//!
//!     cargo run --release --example tree_processing
//!
//! Each tree node carries a value object; the left/right subtrees live in
//! child regions (`n->lreg` / `n->rreg`). `process(top)` doubles every
//! value by recursively spawning `process` on the subregions — nested
//! task parallelism over a pointer-based structure. `print(top)` is
//! spawned with `in(top)` right after, and the runtime schedules it "only
//! when the process task and its children tasks have finished modifying
//! the child regions of top".

use myrmics::config::PlatformConfig;
use myrmics::ids::{ObjectId, RegionId};
use myrmics::platform::Platform;
use myrmics::task::descriptor::TaskArg;
use myrmics::task::registry::Registry;

/// TreeNode: rid_t lreg, rreg; value object; children ids.
#[derive(Clone, Copy, Debug)]
struct TreeNode {
    region: RegionId,
    value: ObjectId,
    left: Option<usize>,
    right: Option<usize>,
}

#[derive(Default)]
struct Tree {
    nodes: Vec<TreeNode>,
}

fn main() {
    let depth = 4u32;
    let mut reg = Registry::new();

    // process(n): double the node's value, recurse into child regions.
    let process = reg.register("process", |ctx| {
        let idx = ctx.val_arg(1) as usize;
        ctx.compute(120_000);
        let node = ctx.world.app_ref::<Tree>().nodes[idx];
        let mut v = ctx.read_f32(node.value);
        for x in &mut v {
            *x *= 2.0;
        }
        ctx.write_f32(node.value, &v);
        let children: Vec<TreeNode> = [node.left, node.right]
            .iter()
            .flatten()
            .map(|&c| ctx.world.app_ref::<Tree>().nodes[c])
            .collect();
        for (i, child) in children.iter().enumerate() {
            let c_idx = if i == 0 { node.left.unwrap() } else { node.right.unwrap() };
            // #pragma myrmics region inout(n->lreg) process(n->left);
            ctx.spawn(
                0,
                vec![TaskArg::region_inout(child.region), TaskArg::val(c_idx as u64)],
            );
        }
    });
    assert_eq!(process, 0);

    // print(root): read-only access to the whole tree; follows pointers
    // freely (paper: "can follow any pointers freely").
    let print = reg.register("print", |ctx| {
        ctx.compute(80_000);
        fn walk(t: &Tree, i: usize, out: &mut Vec<f32>, w: &myrmics::platform::World) {
            let n = t.nodes[i];
            if let Some(l) = n.left {
                walk(t, l, out, w);
            }
            out.push(w.store.get_f32(n.value).unwrap()[0]);
            if let Some(r) = n.right {
                walk(t, r, out, w);
            }
        }
        let mut vals = Vec::new();
        let tree = ctx.world.app_ref::<Tree>();
        walk(tree, 0, &mut vals, ctx.world);
        let total: f32 = vals.iter().sum();
        println!("print task: in-order values sum = {total} over {} nodes", vals.len());
        assert!(vals.iter().all(|v| *v % 2.0 == 0.0), "every node was processed");
    });

    let main_fn = reg.register("main", move |ctx| {
        // Build the tree: each subtree in its own region under the parent.
        fn build(
            ctx: &mut myrmics::api::ctx::TaskCtx<'_>,
            parent_region: RegionId,
            level: u32,
            depth: u32,
            tree: &mut Tree,
        ) -> usize {
            let region = ctx.ralloc(parent_region, level.min(2) as i32);
            let value = ctx.alloc(64, region);
            ctx.write_f32(value, &[(tree.nodes.len() + 1) as f32; 1]);
            let idx = tree.nodes.len();
            tree.nodes.push(TreeNode { region, value, left: None, right: None });
            if level < depth {
                let l = build(ctx, region, level + 1, depth, tree);
                let r = build(ctx, region, level + 1, depth, tree);
                tree.nodes[idx].left = Some(l);
                tree.nodes[idx].right = Some(r);
            }
            idx
        }
        let mut tree = Tree::default();
        let root = build(ctx, RegionId::ROOT, 1, depth, &mut tree);
        let top = tree.nodes[root].region;
        ctx.world.app = Some(Box::new(tree));
        // #pragma myrmics region inout(top)  process(root);
        ctx.spawn(0, vec![TaskArg::region_inout(top), TaskArg::val(root as u64)]);
        // #pragma myrmics region in(top)     print(root);
        ctx.spawn(1, vec![TaskArg::region_in(top), TaskArg::val(root as u64)]);
    });

    let mut platform = Platform::build(PlatformConfig::hierarchical(32), reg, main_fn);
    let cycles = platform.run(Some(1 << 42));
    let w = platform.world();
    let expected_tasks = 1 + (2u64.pow(depth) - 1) + 1; // main + process per node + print
    println!(
        "tree of {} regions processed by {} tasks in {} cycles ({} regions live)",
        2u64.pow(depth) - 1,
        w.gstats.tasks_completed,
        cycles,
        w.mem.n_regions(),
    );
    assert_eq!(w.gstats.tasks_completed, expected_tasks);
    println!("tree_processing OK — print ran after the whole process subtree");
}

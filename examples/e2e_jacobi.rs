//! End-to-end driver: the full three-layer stack on a real workload.
//!
//!     make artifacts && cargo run --release --example e2e_jacobi
//!
//! 1. **Real compute**: runs the Jacobi benchmark on the simulated
//!    heterogeneous platform with task bodies executing the AOT-compiled
//!    Pallas kernel through PJRT (L1 -> L2 -> L3), and verifies the
//!    distributed result against a sequential reference.
//! 2. **Scaling**: sweeps worker counts on the modeled workload and
//!    reports the paper's headline — hierarchical Myrmics tracks the
//!    hand-tuned MPI baseline within ~10-30%.
//!
//! Results are recorded in EXPERIMENTS.md.

use myrmics::apps::jacobi::{jacobi_init, jacobi_reference, myrmics, read_result, JacobiParams};
use myrmics::config::PlatformConfig;
use myrmics::experiments::bench::{run_mpi_bench, run_myrmics, BenchKind, Scaling};
use myrmics::platform::Platform;
use myrmics::runtime::engine::KernelEngine;

fn main() {
    // ---------------------------------------------------- 1. real compute
    let dir = KernelEngine::artifacts_dir();
    if dir.join("jacobi_band.hlo.txt").exists() {
        let kernels = KernelEngine::load(&dir).expect("PJRT CPU client");
        let p = JacobiParams { n: 32, iters: 6, bands: 4, groups: 2, real_data: true };
        let (reg, main) = myrmics();
        let mut plat = Platform::build_with(PlatformConfig::hierarchical(8), reg, main, |w| {
            w.app = Some(Box::new(p));
            w.kernels = Some(kernels);
        });
        let t = plat.run(Some(1 << 44));
        let w = plat.world();
        let got = read_result(w);
        let want = jacobi_reference(32, 6, &jacobi_init(32));
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(g, w)| (g - w).abs())
            .fold(0.0f32, f32::max);
        println!("== e2e real compute (PJRT Pallas kernels) ==");
        println!(
            "jacobi 32x32 x6 iters on 8 workers + 3 schedulers: {} tasks, {} cycles",
            w.gstats.tasks_completed, t
        );
        println!(
            "kernels compiled: {}, max abs error vs sequential reference: {max_err:e}",
            w.kernels.as_ref().unwrap().n_compiled()
        );
        assert!(max_err < 1e-4);
        println!("verification PASS\n");
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT path)\n");
    }

    // ------------------------------------------------------- 2. scaling
    println!("== scaling vs hand-tuned MPI (modeled compute, strong scaling) ==");
    println!(
        "{:>8} {:>14} {:>14} {:>14} {:>10}",
        "workers", "MPI", "myrmics-hier", "speedup", "overhead"
    );
    let mut t1_mpi = 0u64;
    let mut t1_my = 0u64;
    for &w in &[1usize, 4, 16, 64, 128] {
        let (tm, _) = run_mpi_bench(BenchKind::Jacobi, w, Scaling::Strong);
        let (ty, _) = run_myrmics(BenchKind::Jacobi, w, Scaling::Strong, true, None);
        if w == 1 {
            t1_mpi = tm;
            t1_my = ty;
        }
        println!(
            "{w:>8} {tm:>14} {ty:>14} {:>13.1}x {:>9.1}%",
            t1_my as f64 / ty as f64,
            100.0 * (ty as f64 / tm as f64 - 1.0)
        );
        let _ = t1_mpi;
    }
    println!("\npaper headline: similar scalability to MPI with 10-30% overhead");
}

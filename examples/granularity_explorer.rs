//! Explore the task-granularity / worker-count trade-off (Fig 7b/12a).
//!
//!     cargo run --release --example granularity_explorer [--mb] [tasks]
//!
//! Prints the speedup surface for a single scheduler and marks the
//! optimal worker count per task size, which the paper approximates as
//! `task_size / intrinsic_spawn_overhead` (1M / 16.2K ~= 64 workers on
//! the heterogeneous platform).

use myrmics::experiments::fig7::{granularity, optimal_workers, print_granularity};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let hetero = !args.iter().any(|a| a == "--mb");
    let n_tasks: usize = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(256);
    let workers = [1usize, 8, 16, 32, 64, 128, 256];
    let sizes = [100_000u64, 400_000, 1_000_000, 4_000_000];
    let pts = granularity(n_tasks, &workers, &sizes, hetero);
    let label = if hetero {
        "granularity (A9 scheduler, cf. Fig 7b)"
    } else {
        "granularity (MicroBlaze scheduler, cf. Fig 12a)"
    };
    print_granularity(&pts, label);
    let spawn = if hetero { 16_200.0 } else { 37_400.0 };
    for s in sizes {
        let opt = optimal_workers(&pts, s);
        println!(
            "task {s:>9}: optimal {opt:>4} workers (paper predicts ~{:.0})",
            s as f64 / spawn
        );
    }
}
